"""Collaborative serving bench: the batched lax.scan fast path vs the
per-token Python loop (the seed's only mode), the ASYNC pipelined engine
vs the synchronous engine under a simulated server round trip, the WIRE
transport (a real correction-server subprocess over a Unix socket, RTT
and bytes measured, per-request vs coalesced replay), the edge-vs-server
step costs, and the per-stream comms reduction the trigger buys (paper
Fig 4).

Workloads:
  * paper_synthetic (batch 8) — the LM analogue of the paper's synthetic
    experiment at the paper's tiny scale; this is where the scan fast
    path's dispatch-free decode shows its full tokens/sec advantage.
  * paper_synthetic async overlap (batch 8 and 64) — the ``stream``
    transport (JAX async dispatch) with a SERVING_LATENCY_S simulated
    round trip at the SERVING_TRIGGER_RATE operating point: strict-sync
    (max_staleness=0) stalls the whole batch every trigger; the pipelined
    engine hides the RTT behind edge decode (target: >= 1.5x tokens/sec,
    measured end-to-end including the pipeline-tail drain).  The sync run
    is also cross-checked against ``run_scan`` (u/trigger bit-identical).
  * paper_synthetic wire (batch 64, rate 0.3) — TWO processes: a
    ``launch/server.py`` subprocess on a UDS, the engine driving it over
    the ``wire`` transport.  The per-request arm (coalescing off) pays
    one dense masked replay per queued request — the compute-bound floor
    the b64 async bench exposes; the coalesced arm merges the queue into
    one replay per server tick (union of masks, min of positions).
    Latency here is MEASURED on the socket (rtt_mean_ms column), not
    simulated.  Run standalone with ``python benchmarks/bench_serving.py
    --transport wire``.
  * fleet (``--fleet``, batch 64) — TWO correction-server subprocesses
    behind the least-loaded router (serving/fleet.py): a routed arm
    (one redirect hop at HELLO, zero per-token overhead) and a
    SIGKILL-failover arm where the serving process is killed mid-run
    and the client migrates by re-HELLO + full replay — the replay cost
    lands in failovers/failover_tx_kb/replayed_tokens columns while
    u/trigger stay bitwise vs the scan.
  * granite-8b smoke — LM-scale sanity rows (compute-dominated on CPU).
  * adaptive-triggering sweep (``--policy``, batch 64; ``--policy-smoke``
    batch 8 for CI) — {fixed, quantile, budget} threshold policies
    (serving/policy.py) on one paper-synthetic stream with a mid-run
    distribution shift, scored against the always-consult reference
    scan: policy/fn_rate/comms_tokens/frontier columns +
    results/frontier_policy.json, with the budget policy asserted >= 20%
    fewer shipped post-shift tokens than fixed at equal-or-lower FN.
  * slot-pool churn sweep (``--churn``, batch 64) — MonitorSession
    attach/detach at increasing rates: the throughput cost of mid-flight
    stream admission (cohort-split decodes, cold catch-up backlogs) vs
    the fixed-batch baseline, written as churn_rate/tokens_per_sec
    columns to results/bench.csv.
  * mesh-sharding sweep (``--devices N``) — batch {256, 1024} x host
    mesh {1, 2, 4, 8} devices (each point its own subprocess under
    ``XLA_FLAGS=--xla_force_host_platform_device_count``): the
    collective-free sharded monitor path (``SessionConfig(mesh=...)``,
    docs/sharding.md), with per-device super-batch cache bytes —
    devices/batch/tokens_per_sec/cache_bytes_per_device columns.

All arms drive the engine through the public ``MonitorSession`` API
(one ``SessionConfig`` per arm — mode, transport, staleness, coalescing).
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.paper_synthetic import (SERVING as PAPER_SERVING,
                                           SERVING_LATENCY_S,
                                           SERVING_MAX_STALENESS,
                                           SERVING_TRIGGER_RATE,
                                           SERVING_WIRE_SLOTS)
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving import SessionConfig, TransportSpec
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.engine import ServeEngine


def _scan(params, cfg, stream, batch, max_len):
    eng = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
    return eng.session(SessionConfig(mode="scan")).run(stream)


def _bench_pair(name: str, cfg, batch: int, steps: int,
                csv: List[str]) -> None:
    """Per-token loop vs scan path on one config; appends two csv rows."""
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
    max_len = steps + 8

    eng = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
    sess = eng.session()  # sync MonitorSession (the online protocol path)
    warm = 4  # covers trigger AND no-trigger branches (catchup jit included)
    for t in range(warm):
        sess.step(jnp.asarray(stream[:, t]))
    t0 = time.time()
    for t in range(warm, steps):
        sess.step(jnp.asarray(stream[:, t]))
    dt_loop = time.time() - t0
    tps_loop = batch * (steps - warm) / dt_loop
    rep = sess.report()
    csv.append(f"serving/{name}_step,{dt_loop / (steps - warm) * 1e6:.1f},"
               f"tokens_per_sec={tps_loop:.0f};"
               f"trigger_rate={rep['trigger_rate']:.3f};"
               f"reduction={rep['reduction_x']:.2f}x")

    # scan sessions are stateless per run: reuse ONE session so the
    # timed call measures the compiled scan, not trace + engine init
    scan_sess = CollaborativeEngine(
        params, cfg, batch=batch,
        max_len=max_len).session(SessionConfig(mode="scan"))
    scan_sess.run(stream)  # compile
    t0 = time.time()
    res = scan_sess.run(stream)
    dt_scan = time.time() - t0
    tps_scan = batch * steps / dt_scan
    per = res["comms"]["per_stream"]["reduction_x"]
    csv.append(f"serving/{name}_scan,{dt_scan / steps * 1e6:.1f},"
               f"tokens_per_sec={tps_scan:.0f};"
               f"speedup_vs_loop={tps_scan / tps_loop:.1f}x;"
               f"per_stream_reduction={np.round(per, 2).tolist()}")


def _calibrate(cfg, params, stream, batch: int, max_len: int, rate: float):
    """Threshold at the 1-rate quantile of a probe u-trace: per-stream
    trigger rate ~``rate`` (the paper's Fig-4 operating region)."""
    u = _scan(params, cfg, stream, batch, max_len)["u"]
    thr = float(np.quantile(u, 1.0 - rate))
    return cfg.replace(monitor=cfg.monitor.__class__(
        **{**cfg.monitor.__dict__, "threshold": thr, "trigger_margin": 0.0}))


def _bench_async(name: str, cfg, batch: int, steps: int, csv: List[str], *,
                 latency_s: float = SERVING_LATENCY_S,
                 staleness: int = SERVING_MAX_STALENESS,
                 rate: float = SERVING_TRIGGER_RATE) -> None:
    """Async-overlap engine vs the strict-sync engine, both on the SAME
    simulated-RTT ``stream`` transport (latency_s round trip); appends two
    csv rows."""
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
    max_len = steps + 8
    cfg = _calibrate(cfg, params, stream, batch, max_len, rate)
    warm = 6  # covers trigger and no-trigger branches (catchup jit included)

    def timed(max_staleness):
        eng = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
        sess = eng.session(SessionConfig(
            mode="async", max_staleness=max_staleness,
            transport=TransportSpec("stream", latency_s=latency_s)))
        sess.__enter__()
        outs = []
        for t in range(warm):
            outs.append(sess.step(jnp.asarray(stream[:, t])))
        t0 = time.time()
        for t in range(warm, steps):
            outs.append(sess.step(jnp.asarray(stream[:, t])))
        # the pipeline-tail drain is timed too: both arms pay every RTT
        # end-to-end (sync's drain is trivially empty)
        sess.close()
        dt = time.time() - t0
        res = {k: np.stack([o[k] for o in outs], 1)
               for k in ("u", "fhat", "triggered")}
        return eng, res, batch * (steps - warm) / dt

    sync_eng, sync_res, tps_sync = timed(0)
    async_eng, async_res, tps_async = timed(staleness)

    # strict-sync fallback must match the offline scan (protocol identity)
    scan = _scan(params, cfg, stream, batch, max_len)
    assert np.array_equal(sync_res["u"], scan["u"])
    assert np.array_equal(sync_res["triggered"], scan["triggered"])
    np.testing.assert_allclose(sync_res["fhat"], scan["fhat"], atol=1e-6)
    # and the pipelined monitor path is staleness-independent
    assert np.array_equal(async_res["u"], sync_res["u"])
    assert np.array_equal(async_res["triggered"], sync_res["triggered"])

    rep_s = sync_eng.comms.report()["async"]
    rep_a = async_eng.comms.report()["async"]
    trig = float(sync_res["triggered"].mean())
    csv.append(f"serving/{name}_sync_rtt,{1e6 / max(tps_sync, 1e-9) * batch:.1f},"
               f"tokens_per_sec={tps_sync:.0f};trigger_rate={trig:.3f};"
               f"latency_ms={latency_s * 1e3:.0f};"
               f"overlap_ratio={rep_s['overlap_ratio']:.2f};"
               f"stall_s={rep_s['stall_s']:.2f}")
    csv.append(f"serving/{name}_async_rtt,{1e6 / max(tps_async, 1e-9) * batch:.1f},"
               f"tokens_per_sec={tps_async:.0f};"
               f"speedup_vs_sync={tps_async / tps_sync:.2f}x;"
               f"max_staleness={staleness};"
               f"overlap_ratio={rep_a['overlap_ratio']:.2f};"
               f"stall_s={rep_a['stall_s']:.2f};"
               f"inflight_peak={rep_a['inflight_peak']}")


def _bench_wire(name: str, cfg, batch: int, steps: int, csv: List[str], *,
                rate: float = 0.3,
                staleness: int = SERVING_MAX_STALENESS) -> None:
    """Real-boundary bench: per-request replay vs coalesced replay on the
    SAME correction-server subprocess over a Unix socket; appends two csv
    rows with MEASURED RTT and wire byte counts."""
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
    max_len = steps + 8
    cfg = _calibrate(cfg, params, stream, batch, max_len, rate)
    warm = 6  # also absorbs the server-side catch-up jit (first requests)

    from repro.launch.server import spawn_subprocess
    tmp = tempfile.mkdtemp(prefix="bench_wire_")
    uds = os.path.join(tmp, "corr.sock")
    proc = spawn_subprocess("paper-synthetic-serving", uds=uds,
                            slots=max(batch, SERVING_WIRE_SLOTS),
                            max_len=max_len,
                            ready_file=os.path.join(tmp, "ready"),
                            extra_args=("--idle-exit-s", "60"))
    try:
        def timed(coalesce: bool):
            eng = CollaborativeEngine(params, cfg, batch=batch,
                                      max_len=max_len)
            sess = eng.session(SessionConfig(
                mode="async", max_staleness=staleness,
                transport=TransportSpec("wire", address=uds,
                                        coalesce=coalesce)))
            sess.__enter__()
            outs = []
            for t in range(warm):
                outs.append(sess.step(jnp.asarray(stream[:, t])))
            t0 = time.time()
            for t in range(warm, steps):
                outs.append(sess.step(jnp.asarray(stream[:, t])))
            sess.close()  # both arms pay the pipeline-tail drain
            dt = time.time() - t0
            res = {k: np.stack([o[k] for o in outs], 1)
                   for k in ("u", "triggered")}
            return eng, res, batch * (steps - warm) / dt

        perreq_eng, perreq_res, tps_perreq = timed(False)
        coal_eng, coal_res, tps_coal = timed(True)

        # the measured boundary must not change the protocol: u and the
        # trigger trace are bit-identical to the offline scan
        scan = _scan(params, cfg, stream, batch, max_len)
        for res in (perreq_res, coal_res):
            assert np.array_equal(res["u"], scan["u"])
            assert np.array_equal(res["triggered"], scan["triggered"])

        trig = float(coal_res["triggered"].mean())
        for label, eng, tps in (("perreq", perreq_eng, tps_perreq),
                                ("coalesced", coal_eng, tps_coal)):
            rep = eng.comms.report()
            w, a = rep["wire"], rep["async"]
            assert rep["bytes_sent"] <= rep["bytes_baseline"]
            extra = ("" if label == "perreq" else
                     f"speedup_vs_perreq={tps / tps_perreq:.2f}x;")
            csv.append(
                f"serving/{name}_wire_{label},"
                f"{1e6 / max(tps, 1e-9) * batch:.1f},"
                f"tokens_per_sec={tps:.0f};transport=wire;"
                f"coalesce={int(label == 'coalesced')};{extra}"
                f"trigger_rate={trig:.3f};"
                f"rtt_mean_ms={w['rtt_mean_s'] * 1e3:.2f};"
                f"rtt_max_ms={w['rtt_max_s'] * 1e3:.2f};"
                f"wire_tx_kb={w['tx_bytes'] / 1e3:.1f};"
                f"wire_rx_kb={w['rx_bytes'] / 1e3:.1f};"
                f"stall_s={a['stall_s']:.2f}")
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


def _bench_trace(name: str, cfg, batch: int, steps: int, csv: List[str], *,
                 rate: float = 0.3,
                 staleness: int = SERVING_MAX_STALENESS) -> None:
    """The ``--trace`` arm: ONE traced coalesced wire run (same operating
    point as the coalesced ``_bench_wire`` arm, ``SessionConfig(trace=
    True)``), exporting the span trace as Perfetto-loadable JSON to
    ``results/trace_wire_b{batch}.json`` and appending a row whose
    columns are the p50/p99 of the measured RTT and its four stages
    (serialize / socket / queue / compute — docs/observability.md).
    Tracing must not change the protocol: u/trigger stay bitwise vs the
    offline scan, asserted like every other wire arm."""
    from repro.observability import breakdown, load_trace
    from repro.launch.server import spawn_subprocess

    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
    max_len = steps + 8
    cfg = _calibrate(cfg, params, stream, batch, max_len, rate)
    warm = 6

    tmp = tempfile.mkdtemp(prefix="bench_trace_")
    uds = os.path.join(tmp, "corr.sock")
    proc = spawn_subprocess("paper-synthetic-serving", uds=uds,
                            slots=max(batch, SERVING_WIRE_SLOTS),
                            max_len=max_len,
                            ready_file=os.path.join(tmp, "ready"),
                            extra_args=("--idle-exit-s", "60"))
    try:
        eng = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
        sess = eng.session(SessionConfig(
            mode="async", max_staleness=staleness, trace=True,
            transport=TransportSpec("wire", address=uds)))
        sess.__enter__()
        outs = []
        for t in range(warm):
            outs.append(sess.step(jnp.asarray(stream[:, t])))
        t0 = time.time()
        for t in range(warm, steps):
            outs.append(sess.step(jnp.asarray(stream[:, t])))
        sess.close()
        dt = time.time() - t0
        tps = batch * (steps - warm) / dt

        res = {k: np.stack([o[k] for o in outs], 1)
               for k in ("u", "triggered")}
        scan = _scan(params, cfg, stream, batch, max_len)
        assert np.array_equal(res["u"], scan["u"])
        assert np.array_equal(res["triggered"], scan["triggered"])

        out = os.path.join(os.path.dirname(__file__), "..", "results",
                           f"trace_wire_b{batch}.json")
        n_spans = sess.export_trace(out)
        load_trace(out)  # the schema gate (raises on violation)
        stats = breakdown(sess.tracer.spans())
        cols = [f"tokens_per_sec={tps:.0f};transport=wire;coalesce=1;"
                f"trace_spans={n_spans}"]
        for stage in ("rtt", "serialize", "socket", "queue", "compute"):
            s = stats.get(stage)
            if s is not None:
                cols.append(f"{stage}_p50_ms={s['p50_s'] * 1e3:.3f};"
                            f"{stage}_p99_ms={s['p99_s'] * 1e3:.3f}")
        csv.append(f"serving/{name}_wire_traced,"
                   f"{1e6 / max(tps, 1e-9) * batch:.1f},"
                   + ";".join(cols)
                   + f";trace_file=results/trace_wire_b{batch}.json")
        print(f"trace: {n_spans} spans -> {out} (load in "
              "https://ui.perfetto.dev or chrome://tracing)", flush=True)
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


def _bench_shm(name: str, cfg, batch: int, steps: int, csv: List[str], *,
               rate: float = 0.3,
               staleness: int = SERVING_MAX_STALENESS) -> None:
    """The ``--transport shm`` arm: the SAME operating point as the
    coalesced ``_bench_wire`` arm, but the server subprocess is started
    with ``--transport shm`` and the client attaches through a
    ``TransportSpec("shm", ...)`` — payload frames ride the mmap'd
    same-host ring pair, only the lease lifecycle stays on the control
    socket.  Traced (``SessionConfig(trace=True)``) so the run exports
    ``results/trace_shm_b{batch}.json`` and the row carries the
    stage-breakdown p50/p99 columns next to the wire_traced row: the
    ``socket`` stage (now the ``shm.ring`` span) is where the collapse
    shows.  u/trigger stay bitwise vs the offline scan, and the bytes
    must land in the ``shm`` comms bucket, not ``wire``."""
    from repro.observability import breakdown, load_trace
    from repro.launch.server import spawn_subprocess

    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
    max_len = steps + 8
    cfg = _calibrate(cfg, params, stream, batch, max_len, rate)
    warm = 6

    tmp = tempfile.mkdtemp(prefix="bench_shm_")
    uds = os.path.join(tmp, "corr.sock")
    proc = spawn_subprocess("paper-synthetic-serving", uds=uds,
                            slots=max(batch, SERVING_WIRE_SLOTS),
                            max_len=max_len,
                            ready_file=os.path.join(tmp, "ready"),
                            extra_args=("--idle-exit-s", "60",
                                        "--transport", "shm"))
    try:
        eng = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
        sess = eng.session(SessionConfig(
            mode="async", max_staleness=staleness, trace=True,
            transport=TransportSpec("shm", address=uds)))
        sess.__enter__()
        outs = []
        for t in range(warm):
            outs.append(sess.step(jnp.asarray(stream[:, t])))
        t0 = time.time()
        for t in range(warm, steps):
            outs.append(sess.step(jnp.asarray(stream[:, t])))
        sess.close()
        dt = time.time() - t0
        tps = batch * (steps - warm) / dt

        res = {k: np.stack([o[k] for o in outs], 1)
               for k in ("u", "triggered")}
        scan = _scan(params, cfg, stream, batch, max_len)
        assert np.array_equal(res["u"], scan["u"])
        assert np.array_equal(res["triggered"], scan["triggered"])

        rep = eng.comms.report()
        s, a = rep["shm"], rep["async"]
        assert s["replies"] > 0, "shm arm fell back to plain wire"
        assert rep["bytes_sent"] <= rep["bytes_baseline"]

        out = os.path.join(os.path.dirname(__file__), "..", "results",
                           f"trace_shm_b{batch}.json")
        n_spans = sess.export_trace(out)
        load_trace(out)  # the schema gate (raises on violation)
        stats = breakdown(sess.tracer.spans())
        cols = [f"tokens_per_sec={tps:.0f};transport=shm;coalesce=1;"
                f"trace_spans={n_spans};"
                f"rtt_mean_ms={s['rtt_mean_s'] * 1e3:.2f};"
                f"rtt_max_ms={s['rtt_max_s'] * 1e3:.2f};"
                f"shm_tx_kb={s['tx_bytes'] / 1e3:.1f};"
                f"shm_rx_kb={s['rx_bytes'] / 1e3:.1f};"
                f"stall_s={a['stall_s']:.2f}"]
        for stage in ("rtt", "serialize", "socket", "queue", "compute"):
            st = stats.get(stage)
            if st is not None:
                cols.append(f"{stage}_p50_ms={st['p50_s'] * 1e3:.3f};"
                            f"{stage}_p99_ms={st['p99_s'] * 1e3:.3f}")
        csv.append(f"serving/{name}_shm,"
                   f"{1e6 / max(tps, 1e-9) * batch:.1f},"
                   + ";".join(cols)
                   + f";trace_file=results/trace_shm_b{batch}.json")
        print(f"shm trace: {n_spans} spans -> {out}", flush=True)
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


def _bench_churn(name: str, cfg, batch: int, steps: int, csv: List[str], *,
                 rates=(0.0, 0.05, 0.1, 0.2), rate: float = 0.3,
                 seed: int = 0) -> None:
    """Slot-pool churn sweep (MonitorSession.attach/detach) at fixed
    batch: at churn rate r, each step detaches the oldest stream and
    admits a fresh one with probability r*batch (expected r*batch
    membership changes per step).  Appends one csv row per rate with
    ``churn_rate`` and ``tokens_per_sec`` columns — the cost of mid-
    flight admission (cohort-split decodes + cold catch-up backlogs)
    relative to the fixed-batch baseline (rate 0)."""
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    probe = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
    max_len = steps + 8
    cfg = _calibrate(cfg, params, probe, batch, max_len, rate)
    rng = np.random.default_rng(seed)
    # one long token pool: stream k reads row k % batch shifted by k
    pool = next(tok.lm_batches(1, cfg, batch, max_len))["tokens"]
    warm = 4

    for churn in rates:
        eng = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
        sess = eng.session()
        born = {sid: 0 for sid in sess.streams}  # id -> first step
        next_id = batch
        tokens_served = 0
        t0 = None
        for t in range(steps):
            if t == warm:
                t0 = time.time()
            n_events = rng.binomial(batch, churn) if churn > 0 else 0
            for _ in range(n_events):
                oldest = min(sess.streams, key=born.get)
                sess.detach(oldest)
                born.pop(oldest)
                sess.attach(next_id)
                born[next_id] = t
                next_id += 1
            toks = {sid: pool[sid % batch, t - born[sid]]
                    for sid in sess.streams}
            sess.step(toks)
            if t >= warm:
                tokens_served += sess.n_attached
        dt = time.time() - t0
        tps = tokens_served / dt
        rep = sess.report()
        csv.append(f"serving/{name}_churn,{dt / (steps - warm) * 1e6:.1f},"
                   f"churn_rate={churn:.2f};tokens_per_sec={tps:.0f};"
                   f"trigger_rate={rep['trigger_rate']:.3f};"
                   f"streams_admitted={next_id - batch};"
                   f"reduction={rep['reduction_x']:.2f}x")


def _bench_policy(name: str, cfg, batch: int, steps_pre: int, steps_post: int,
                  csv: List[str], *, rate: float = 0.3, target: float = 0.05,
                  assert_frontier: bool = True) -> None:
    """The ``--policy`` arm: {fixed, quantile, budget} threshold policies
    on the SAME paper-synthetic stream with a mid-run distribution shift
    (the post window's tokens collapse to a narrow low-id band, so u
    drops and the calibrated operating point over-consults).

    Ground truth is the always-consult reference scan (threshold
    ``-1e9``): because catch-up replays the same history, corrections on
    consulted steps equal the reference exactly, so ``fn_rate`` — the
    rate of reference alarms (``fhat_ref > gamma``) a policy run missed
    — is STRUCTURALLY ZERO under sign-constrained corrections (a skip
    leaves ``fhat = u >= fhat_ref`` standing).  It is measured and
    asserted, not assumed; the real frontier cost axis is
    ``fp_excess_rate`` (raw-u alarms a consult would have cleared) and
    ``uncorrected_rate`` (skipped alarm candidates).

    Appends one row per policy with policy/fn_rate/comms_tokens/frontier
    columns and writes the comms-vs-FN frontier to
    ``results/frontier_policy.json``.  ``assert_frontier`` additionally
    asserts the budget policy's acceptance numbers: >= 20% fewer shipped
    post-shift tokens than fixed at equal-or-lower FN, and a realized
    post-shift trigger rate within +20% of its comms-target CEILING
    (the target is a budget, not a setpoint — a silent stream is under
    budget, not out of spec)."""
    import json

    from repro.serving import BudgetPolicy, FixedPolicy, QuantilePolicy

    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    pre = next(tok.lm_batches(0, cfg, batch, steps_pre))["tokens"]
    post = next(tok.lm_batches(1, cfg, batch, steps_post))["tokens"] % 8 + 200
    stream = np.concatenate([pre, post], axis=1).astype(np.int32)
    steps = steps_pre + steps_post
    max_len = steps + 8

    # always-consult reference over the FULL stream: alarm ground truth
    # + the calibration probe (thr at the 1-rate quantile of PRE u only)
    cfg_ref = cfg.replace(monitor=cfg.monitor.__class__(
        **{**cfg.monitor.__dict__, "threshold": -1e9, "trigger_margin": 0.0}))
    ref = _scan(params, cfg_ref, stream, batch, max_len)
    u_ref = np.asarray(ref["u"])
    fhat_ref = np.asarray(ref["fhat"])
    thr = float(np.quantile(u_ref[:, :steps_pre], 1.0 - rate))
    gamma = thr  # alarm level == the calibrated operating point
    cfg = cfg.replace(monitor=cfg.monitor.__class__(
        **{**cfg.monitor.__dict__, "threshold": thr, "trigger_margin": 0.0}))
    alarms_ref = fhat_ref > gamma

    policies = [
        ("fixed", FixedPolicy()),
        ("quantile", QuantilePolicy(2 * target, window=48, min_samples=16)),
        ("budget", BudgetPolicy(target, fn_budget=0.15, window=32,
                                min_evidence=4)),
    ]
    warm = 4
    frontier = []
    by_name = {}
    for pname, pol in policies:
        eng = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
        sess = eng.session(SessionConfig(mode="sync", policy=pol))
        us, fhats, trigs = [], [], []
        shipped_mid = 0
        t0 = None
        with sess:
            for t in range(steps):
                if t == warm:
                    t0 = time.time()
                r = sess.step(jnp.asarray(stream[:, t]))
                us.append(r["u"]); fhats.append(r["fhat"])
                trigs.append(r["triggered"])
                if t == steps_pre - 1:  # meter snapshot at the shift
                    shipped_mid = eng.comms.tokens_shipped
        dt = time.time() - t0
        tps = batch * (steps - warm) / dt
        u = np.stack(us, 1); fhat = np.stack(fhats, 1)
        trig = np.stack(trigs, 1)
        # policies only move the trigger point: u is policy-independent
        assert np.array_equal(u, u_ref), pname
        assert (fhat <= u).all(), pname
        alarms_pol = fhat > gamma
        post = slice(steps_pre, steps)
        fn = float((alarms_ref[:, post] & ~alarms_pol[:, post]).mean())
        fp_x = float((alarms_pol[:, post] & ~alarms_ref[:, post]).mean())
        uncor = float(((u[:, post] > gamma) & ~trig[:, post]).mean())
        # sign-safety makes missed alarms structurally impossible —
        # measured, not assumed
        assert fn == 0.0, (pname, fn)
        shipped_post = eng.comms.tokens_shipped - shipped_mid
        rep = eng.comms.report()
        point = {
            "policy": pname,
            "target_rate": getattr(pol, "target_rate", None),
            "fn_rate": fn,
            "fp_excess_rate": fp_x,
            "uncorrected_rate": uncor,
            "post_shipped_tokens": int(shipped_post),
            "pre_shipped_tokens": int(shipped_mid),
            "post_trigger_rate": float(trig[:, post].mean()),
            "bytes_sent": int(rep["bytes_sent"]),
            "reduction_x": float(rep["reduction_x"]),
        }
        frontier.append(point)
        by_name[pname] = point
        vs_fixed = (shipped_post / max(by_name["fixed"]["post_shipped_tokens"], 1))
        csv.append(
            f"serving/{name}_policy_{pname},"
            f"{dt / (steps - warm) * 1e6:.1f},"
            f"policy={pname};tokens_per_sec={tps:.0f};"
            f"fn_rate={fn:.4f};fp_excess_rate={fp_x:.4f};"
            f"uncorrected_rate={uncor:.4f};"
            f"comms_tokens={shipped_post};"
            f"comms_tokens_total={eng.comms.tokens_shipped};"
            f"post_trigger_rate={point['post_trigger_rate']:.4f};"
            f"frontier=post_tokens_vs_fixed:{vs_fixed:.2f}x")

    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "frontier_policy.json")
    with open(out, "w") as fh:
        json.dump({"batch": batch, "steps_pre": steps_pre,
                   "steps_post": steps_post, "calibration_rate": rate,
                   "threshold": thr, "frontier": frontier}, fh, indent=2)
    print(f"frontier -> {out}", flush=True)

    bud, fix = by_name["budget"], by_name["fixed"]
    # the comms target is a CEILING: realized rate must not exceed it by
    # more than 20% (sitting under budget — triggers ceasing on silent
    # streams — is the point, not a violation)
    assert bud["post_trigger_rate"] <= 1.2 * target, (
        bud["post_trigger_rate"], target)
    if assert_frontier:
        assert bud["fn_rate"] <= fix["fn_rate"]
        assert bud["post_shipped_tokens"] <= 0.8 * fix["post_shipped_tokens"], (
            bud["post_shipped_tokens"], fix["post_shipped_tokens"])


def run_policy(csv: List[str], *, smoke: bool = False) -> None:
    """The ``--policy`` arm rows only.  ``smoke``: the CI-sized sweep
    (batch 8) — the budget-ceiling assert still runs, the >= 20%
    frontier assert is batch-64 acceptance only."""
    n0 = len(csv)
    if smoke:
        _bench_policy("paper_synthetic_b8", PAPER_SERVING, batch=8,
                      steps_pre=48, steps_post=48, csv=csv,
                      assert_frontier=False)
    else:
        _bench_policy("paper_synthetic_b64", PAPER_SERVING, batch=64,
                      steps_pre=96, steps_post=96, csv=csv)
    for row in csv[n0:]:
        print(row, flush=True)


def _mesh_child_row(devices: int, batch: int, steps: int = 20) -> str:
    """Runs INSIDE the child process (XLA_FLAGS already pinned by the
    parent): one sharded sync session on the collective-free monitor
    path (threshold pushed above every u, so no stream triggers — the
    mesh scales the every-token edge path; the trigger path is the
    server's own bench).  Returns the csv row."""
    import dataclasses

    from repro.serving import mesh as mesh_mod

    assert jax.device_count() >= devices, (jax.device_count(), devices)
    cfg = PAPER_SERVING.replace(monitor=dataclasses.replace(
        PAPER_SERVING.monitor, threshold=1e9, trigger_margin=0.0))
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
    eng = CollaborativeEngine(params, cfg, batch=batch, max_len=steps + 4,
                              mesh=f"data:{devices}")
    sess = eng.session()
    warm = 3
    for t in range(warm):
        sess.step(jnp.asarray(stream[:, t]))
    t0 = time.time()
    for t in range(warm, steps):
        sess.step(jnp.asarray(stream[:, t]))
    dt = time.time() - t0
    tps = batch * (steps - warm) / dt
    cache_bytes = (mesh_mod.bytes_per_device(eng.server.cache)
                   + mesh_mod.bytes_per_device(eng.edge.cache))
    # note: on a virtual-device CPU host the sweep measures MEMORY
    # scaling (per-device cache bytes drop 1/N), not throughput — the
    # caveat travels with the row instead of living only in ROADMAP prose
    return (f"serving/mesh_b{batch}_d{devices},"
            f"{dt / (steps - warm) * 1e6:.1f},"
            f"devices={devices};batch={batch};tokens_per_sec={tps:.0f};"
            f"cache_bytes_per_device={cache_bytes};"
            f"note=cache-bytes-motivated")


def run_mesh_sweep(csv: List[str], max_devices: int) -> None:
    """The ``--devices N`` arm: spawn one subprocess per (devices, batch)
    point — the placeholder host device count is an XLA startup flag, so
    each point needs its own jax process — and collect the
    devices/batch/tokens_per_sec/cache_bytes_per_device rows."""
    n0 = len(csv)
    for devices in (1, 2, 4, 8):
        if devices > max_devices:
            continue
        for batch in (256, 1024):
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                env.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={devices}"
            ).strip()
            r = subprocess.run(
                [sys.executable, os.path.abspath(__file__),
                 "--_mesh-child", str(devices), str(batch)],
                capture_output=True, text=True, env=env, timeout=1200)
            if r.returncode != 0:
                raise RuntimeError(
                    f"mesh child d={devices} b={batch} failed:\n"
                    + r.stderr[-2000:])
            rows = [l[len("MESHROW "):] for l in r.stdout.splitlines()
                    if l.startswith("MESHROW ")]
            assert len(rows) == 1, r.stdout[-2000:]
            csv.extend(rows)
    for row in csv[n0:]:
        print(row, flush=True)


def _bench_fleet(name: str, cfg, batch: int, steps: int, csv: List[str], *,
                 rate: float = 0.3,
                 staleness: int = SERVING_MAX_STALENESS) -> None:
    """Fleet bench: TWO correction-server subprocesses behind the
    least-loaded router (serving/fleet.py), a batch-``batch`` client
    attached through a ``fleet:`` address.  Two arms: the routed run
    (router adds one redirect hop at HELLO, zero per-token overhead) and
    the same run with a SIGKILL of the serving process mid-flight — the
    failover arm prices the re-HELLO + full replay migration
    (``comms['failover']``) while u/trigger stay bitwise vs the scan."""
    import threading

    from repro.serving.fleet import FleetSupervisor

    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
    max_len = steps + 8
    cfg = _calibrate(cfg, params, stream, batch, max_len, rate)
    warm = 6

    sup = FleetSupervisor("paper-synthetic-serving", n_servers=2,
                          slots=max(batch, SERVING_WIRE_SLOTS),
                          max_len=max_len, backend="subprocess",
                          respawn=False)
    stop = threading.Event()
    watcher = threading.Thread(target=sup.run_forever, args=(stop,),
                               daemon=True)
    try:
        sup.start(wait=True)
        watcher.start()

        def timed(kill_at=None):
            eng = CollaborativeEngine(params, cfg, batch=batch,
                                      max_len=max_len)
            sess = eng.session(SessionConfig(
                mode="async", max_staleness=staleness,
                transport=TransportSpec(
                    "wire", address="fleet:" + sup.router_address)))
            sess.__enter__()
            outs = []
            for t in range(warm):
                outs.append(sess.step(jnp.asarray(stream[:, t])))
            t0 = time.time()
            for t in range(warm, steps):
                outs.append(sess.step(jnp.asarray(stream[:, t])))
                if kill_at == t:
                    victim = next(h for h in sup.servers.values()
                                  if h.address == eng._worker.server_address)
                    victim.kill()   # a real SIGKILL, no goodbye
            sess.close()
            dt = time.time() - t0
            res = {k: np.stack([o[k] for o in outs], 1)
                   for k in ("u", "triggered")}
            return eng, res, batch * (steps - warm) / dt

        routed_eng, routed_res, tps_routed = timed()
        kill_eng, kill_res, tps_kill = timed(kill_at=(warm + steps) // 2)

        # routing and failover must not change the protocol
        scan = _scan(params, cfg, stream, batch, max_len)
        for res in (routed_res, kill_res):
            assert np.array_equal(res["u"], scan["u"])
            assert np.array_equal(res["triggered"], scan["triggered"])

        trig = float(routed_res["triggered"].mean())
        for label, eng, tps in (("routed", routed_eng, tps_routed),
                                ("failover", kill_eng, tps_kill)):
            rep = eng.comms.report()
            w = rep["wire"]
            fo = rep.get("failover", {"failovers": 0, "tx_bytes": 0,
                                      "replayed_tokens": 0})
            assert fo["failovers"] == (1 if label == "failover" else 0)
            csv.append(
                f"serving/{name}_fleet_{label},"
                f"{1e6 / max(tps, 1e-9) * batch:.1f},"
                f"tokens_per_sec={tps:.0f};transport=fleet;"
                f"n_servers=2;trigger_rate={trig:.3f};"
                f"failovers={fo['failovers']};"
                f"failover_tx_kb={fo['tx_bytes'] / 1e3:.1f};"
                f"replayed_tokens={fo['replayed_tokens']};"
                f"wire_tx_kb={w['tx_bytes'] / 1e3:.1f};"
                f"rtt_mean_ms={w['rtt_mean_s'] * 1e3:.2f}")
    finally:
        stop.set()
        watcher.join(timeout=10)
        sup.close()


def run_churn(csv: List[str]) -> None:
    """The churn-sweep rows only (bench_serving --churn)."""
    n0 = len(csv)
    _bench_churn("paper_synthetic_b64", PAPER_SERVING, batch=64, steps=96,
                 csv=csv)
    for row in csv[n0:]:
        print(row, flush=True)


def run_wire(csv: List[str]) -> None:
    """The wire-transport rows only (the acceptance operating point)."""
    n0 = len(csv)
    _bench_wire("paper_synthetic_b64", PAPER_SERVING, batch=64, steps=96,
                csv=csv, rate=0.3)
    for row in csv[n0:]:
        print(row, flush=True)


def run_shm(csv: List[str]) -> None:
    """The shm-transport row only (bench_serving --transport shm):
    traced same-host ring run + results/trace_shm_b64.json."""
    n0 = len(csv)
    _bench_shm("paper_synthetic_b64", PAPER_SERVING, batch=64, steps=96,
               csv=csv, rate=0.3)
    for row in csv[n0:]:
        print(row, flush=True)


def run_trace(csv: List[str]) -> None:
    """The traced-wire row only (bench_serving --trace): Perfetto trace
    export + the p50/p99 RTT-breakdown columns."""
    n0 = len(csv)
    _bench_trace("paper_synthetic_b64", PAPER_SERVING, batch=64, steps=96,
                 csv=csv, rate=0.3)
    for row in csv[n0:]:
        print(row, flush=True)


def run_fleet(csv: List[str]) -> None:
    """The fleet rows only (routed + SIGKILL-failover arms)."""
    n0 = len(csv)
    _bench_fleet("paper_synthetic_b64", PAPER_SERVING, batch=64, steps=96,
                 csv=csv, rate=0.3)
    for row in csv[n0:]:
        print(row, flush=True)


def run(csv: List[str]) -> None:
    n0 = len(csv)
    # paper-synthetic scale, batch 8: the scan fast path's headline number
    _bench_pair("paper_synthetic", PAPER_SERVING, batch=8, steps=64, csv=csv)

    # async overlap vs strict sync under a simulated server round trip.
    # batch 64 runs at the dense end of the paper's Fig-4 operating region
    # (rate 0.3): shorter backlogs keep the masked replay — which is dense
    # over the batch — from dominating the async floor (see ROADMAP:
    # worker-side request coalescing)
    _bench_async("paper_synthetic_b8", PAPER_SERVING, batch=8, steps=96,
                 csv=csv)
    _bench_async("paper_synthetic_b64", PAPER_SERVING, batch=64, steps=96,
                 csv=csv, rate=0.3)

    # the REAL boundary: correction-server subprocess over a Unix socket,
    # measured RTT/bytes, per-request vs coalesced replay (ROADMAP:
    # real transport + worker-side request coalescing)
    _bench_wire("paper_synthetic_b64", PAPER_SERVING, batch=64, steps=96,
                csv=csv, rate=0.3)

    # LM smoke scale
    cfg = registry.get_smoke("granite-8b")
    _bench_pair("collab", cfg, batch=4, steps=48, csv=csv)

    # server-only baseline (every token through the big tower)
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, 4, 48))["tokens"]
    se = ServeEngine(params["server"], cfg, batch=4, max_len=64)
    se.decode(jnp.asarray(stream[:, 0]))
    t0 = time.time()
    for t in range(1, 33):
        se.decode(jnp.asarray(stream[:, t]))
    us_srv = (time.time() - t0) / 32 * 1e6
    csv.append(f"serving/server_only_step,{us_srv:.1f},edge_vs_server_note="
               f"smoke-scale")
    for row in csv[n0:]:
        print(row, flush=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--transport", choices=("all", "wire", "shm"),
                    default="all",
                    help="'wire' runs only the two-process socket bench; "
                         "'shm' runs the same operating point over the "
                         "same-host shared-memory ring transport (traced, "
                         "exports results/trace_shm_b64.json); both append "
                         "their rows to results/bench.csv")
    ap.add_argument("--fleet", action="store_true",
                    help="run only the fleet bench: 2 correction-server "
                         "subprocesses behind the least-loaded router, a "
                         "batch-64 client through a fleet: address, one "
                         "routed arm and one SIGKILL-failover arm, "
                         "appending failovers/failover_tx_kb/"
                         "tokens_per_sec rows to results/bench.csv")
    ap.add_argument("--trace", action="store_true",
                    help="run only the traced coalesced wire bench "
                         "(batch 64, SessionConfig(trace=True)): exports "
                         "results/trace_wire_b64.json (Perfetto-loadable) "
                         "and appends a row with serialize/socket/queue/"
                         "compute p50/p99 ms columns to results/bench.csv")
    ap.add_argument("--policy", action="store_true",
                    help="run only the adaptive-triggering sweep: {fixed, "
                         "quantile, budget} threshold policies at batch 64 "
                         "on a paper-synthetic stream with a mid-run "
                         "distribution shift, appending policy/fn_rate/"
                         "comms_tokens/frontier rows to results/bench.csv "
                         "and writing results/frontier_policy.json")
    ap.add_argument("--policy-smoke", action="store_true",
                    help="the CI-sized policy sweep (batch 8): same shift "
                         "and columns, asserts the budget policy's realized "
                         "post-shift trigger rate stays within +20%% of its "
                         "comms-target ceiling")
    ap.add_argument("--churn", action="store_true",
                    help="run only the slot-pool churn sweep (attach/"
                         "detach rates at batch 64) and append its "
                         "churn_rate/tokens_per_sec rows to "
                         "results/bench.csv")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="run only the mesh-sharding sweep: batch {256,"
                         "1024} x devices {1,2,4,8} up to N, each point "
                         "in its own subprocess under XLA_FLAGS="
                         "--xla_force_host_platform_device_count, "
                         "appending devices/batch/tokens_per_sec/"
                         "cache_bytes_per_device rows to results/bench.csv")
    ap.add_argument("--_mesh-child", nargs=2, type=int, default=None,
                    metavar=("D", "B"), help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args._mesh_child is not None:
        print("MESHROW " + _mesh_child_row(*args._mesh_child), flush=True)
        sys.exit(0)
    rows: List[str] = []
    if (args.transport != "all" or args.churn or args.fleet or args.trace
            or args.policy or args.policy_smoke or args.devices is not None):
        if args.policy or args.policy_smoke:
            run_policy(rows, smoke=args.policy_smoke)
        elif args.churn:
            run_churn(rows)
        elif args.fleet:
            run_fleet(rows)
        elif args.trace:
            run_trace(rows)
        elif args.devices is not None:
            run_mesh_sweep(rows, args.devices)
        elif args.transport == "shm":
            run_shm(rows)
        else:
            run_wire(rows)
        out = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench.csv")
        with open(out, "a") as fh:
            fh.write("\n".join(rows) + "\n")
        print(f"appended {len(rows)} rows to {out}")
    else:
        run(rows)

"""Quickstart: the paper's pipeline end-to-end on the synthetic dataset.

1. Generate f(x) = sum_i 0.9^{i-1} cos(ix)  (paper §4.1)
2. Calibrate the safety offset t(n) and scale s = 2 t(n)  (Props 2+3)
3. Train f_hat = u_{n,t} - s*sigma(v) end-to-end with Adam
4. Report the §2.3 metrics: approximation error, FP, FN (must be ~0)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.paper_synthetic import FULL as SYN
from repro.core import safety, theory
from repro.data.synthetic import paper_synthetic, synthetic_residual
from repro.training.loop import train_paper


def main() -> None:
    n, n_modes = 12, 48
    x, f = paper_synthetic(0, 4096, rho=SYN.rho, n_modes=n_modes)

    # --- theory-guided design (this is the paper's contribution) ----------
    t = theory.t_of_n_sampled(
        lambda z: synthetic_residual(z, n, rho=SYN.rho, n_modes=n_modes), x)
    s = theory.s_rule(t)  # s = 2 t(n): safe AND minimal false positives
    print(f"monitor truncation n={n}:  t(n)={t:.4f}  ->  s=2t={s:.4f}")
    print(f"(closed form for exp decay: s ~ rho^n/(1-rho) = "
          f"{theory.exp_decay_s(SYN.rho, n):.4f})")

    # --- end-to-end training ----------------------------------------------
    params, res = train_paper(jax.random.PRNGKey(0), SYN, x, f,
                              u_mode="cosine", n_modes=n_modes, monitor_n=n,
                              s=s, freeze_t=t, steps=1500, lr=5e-3,
                              log_fn=print)
    out = res["out"]
    rep = safety.metrics_report(jnp.asarray(f), out["u"], out["fhat"], eps=0.05)
    print("\n=== paper §2.3 metrics ===")
    for k, v in rep.items():
        print(f"  {k:24s} {float(v):.5f}")
    assert float(rep["fn"]) < 0.005, "safety broken!"
    print("\nOK: on-device monitor is SAFE (FN ~ 0) at "
          f"{n}/{n_modes} of the basis complexity.")


if __name__ == "__main__":
    main()

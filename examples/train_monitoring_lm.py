"""End-to-end driver: train a collaborative monitoring LM for a few hundred
steps on CPU — the server tower learns next-token prediction while the
edge tower + truncated-basis head learn the per-position health index with
the safety hinge.

Any assigned architecture works via --arch (reduced variant for CPU);
writes a loss-curve CSV to results/train_<arch>.csv.

Run:  PYTHONPATH=src python examples/train_monitoring_lm.py \
          --arch zamba2-7b --steps 200
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import csv

import jax

from repro.configs import registry
from repro.data import tokens as tok
from repro.training.loop import train_collab_lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=registry.names())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = registry.get_smoke(args.arch)
    print(f"arch={cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model}) "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")
    batches = tok.lm_batches(0, cfg, args.batch, args.seq)
    params, hist = train_collab_lm(jax.random.PRNGKey(0), cfg, batches,
                                   steps=args.steps, lr=args.lr, log_every=10)

    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       f"train_{args.arch}.csv")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as fh:
        w = csv.DictWriter(fh, fieldnames=list(hist[0]))
        w.writeheader()
        w.writerows(hist)
    print(f"\nwrote {len(hist)} records to {out}")
    first, last = hist[0], hist[-1]
    print(f"loss {first['total']:.3f} -> {last['total']:.3f}   "
          f"monitor {first['monitor']:.3f} -> {last['monitor']:.3f}   "
          f"safety {first['safety']:.4f} -> {last['safety']:.4f}")


if __name__ == "__main__":
    main()

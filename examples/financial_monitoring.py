"""Paper §4.2: financial monitoring with an edge/server split.

Trains V = FC(29,64,128,256,1) on the 30-ticker panel, truncates the
penultimate layer to 16 units for the on-device monitor, and serves the
stream with threshold triggering — reporting the paper's headline numbers:
FN = 0, ~6x on-device compression, ~10x communication reduction.

Run:  PYTHONPATH=src python examples/financial_monitoring.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_financial import FULL as FIN
from repro.core import safety
from repro.core.gating import CommsMeter, trigger_mask
from repro.data.synthetic import financial_series, financial_xy
from repro.nn.module import param_count
from repro.training.loop import train_paper


def main() -> None:
    panel = financial_series(0)
    x, f = financial_xy(panel)
    print(f"panel: {panel.shape[0]} days x {panel.shape[1]} tickers, "
          f"warning threshold gamma={FIN.threshold}")

    params, res = train_paper(jax.random.PRNGKey(0), FIN, x, f,
                              u_mode="truncated", steps=2500, lr=2e-3,
                              safety_weight=20.0, log_fn=print)
    out = res["out"]
    rep = safety.metrics_report(jnp.asarray(f), out["u"], out["fhat"],
                                eps=0.01, threshold=FIN.threshold)
    print("\n=== monitoring metrics (threshold 0.8) ===")
    for k in ("l2", "fn", "fp", "corrected_fp", "safety_violation_rate"):
        print(f"  {k:24s} {float(rep[k]):.5f}")

    mask = np.asarray(trigger_mask(out["u"], FIN.threshold, 0.05))
    meter = CommsMeter(bytes_per_request=29 * 4)
    meter.update(int(mask.sum()), mask.size)
    v_size = param_count(params["v"])
    u_size = FIN.monitor_n + 1 + sum(
        d1 * d2 + d2 for d1, d2 in
        zip((FIN.in_dim,) + FIN.hidden[:-1], FIN.hidden[:-1] + (FIN.monitor_n,)))
    print(f"\non-device size: {u_size:,} params vs server {v_size:,} "
          f"({v_size/u_size:.1f}x compression)")
    print(f"communication: trigger rate {meter.trigger_rate:.3f} -> "
          f"{meter.reduction:.1f}x reduction vs ship-everything")


if __name__ == "__main__":
    main()

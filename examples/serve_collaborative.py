"""Collaborative serving demo: batched token streams monitored on the edge
tower; the server backbone is consulted ONLY when the monitor trips the
warning threshold (paper Fig 1 protocol, LM scale).

Trains briefly first so the monitor is meaningful, then serves and prints
the per-stream alarm trace + communication report.

Run:  PYTHONPATH=src python examples/serve_collaborative.py --arch granite-8b
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data import tokens as tok
from repro.serving.collaborative import CollaborativeEngine
from repro.training.loop import train_collab_lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=registry.names())
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--length", type=int, default=48)
    args = ap.parse_args()

    cfg = registry.get_smoke(args.arch)
    print(f"training monitor briefly ({args.train_steps} steps)...")
    batches = tok.lm_batches(0, cfg, args.streams, 64)
    params, _ = train_collab_lm(jax.random.PRNGKey(0), cfg, batches,
                                steps=args.train_steps, lr=1e-3, log_every=20)

    print(f"\nserving {args.streams} streams x {args.length} tokens "
          f"(threshold={cfg.monitor.threshold}, "
          f"margin={cfg.monitor.trigger_margin})")
    stream = next(tok.lm_batches(9, cfg, args.streams, args.length))["tokens"]
    eng = CollaborativeEngine(params, cfg, batch=args.streams,
                              max_len=args.length + 8)
    res = eng.run(stream)

    for b in range(args.streams):
        trace = "".join("!" if t else "." for t in res["triggered"][b])
        print(f"  stream {b}: {trace}")
    rep = res["comms"]
    print(f"\ntrigger rate {rep['trigger_rate']:.3f}  |  "
          f"bytes {rep['bytes_sent']:,} vs baseline {rep['bytes_baseline']:,} "
          f"->  {rep['reduction_x']:.1f}x communication reduction")
    print("fhat <= u everywhere:",
          bool(np.all(res["fhat"] <= res["u"] + 1e-6)))


if __name__ == "__main__":
    main()

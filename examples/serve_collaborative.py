"""Collaborative serving demo: batched token streams monitored on the edge
tower; the server backbone is consulted ONLY when the monitor trips the
warning threshold (paper Fig 1 protocol, LM scale).  Every stream keeps its
own backlog and server catch-up position — a trigger on one stream never
touches another stream's comms account.

Everything is served through the public ``MonitorSession`` API (one
``SessionConfig`` per arm — see docs/api.md).  Trains briefly first so
the monitor is meaningful, then serves a sync session (the online
per-element protocol loop), re-evaluates the same traces through a scan
session (compiled lax.scan fast path), and finally serves an ASYNC
session (the catch-up overlaps edge decode; the monitor/trigger path is
bit-identical, corrections merge one step late) — printing per-stream
alarm traces, the per-stream communication report, the offline-
evaluation speedup, and the async overlap accounting.

With ``--wire`` the demo goes end-to-end across a REAL process boundary:
it checkpoints the trained params, spawns a correction-server subprocess
(``launch/server.py --ckpt-dir ...``) on a Unix socket, and serves the
same streams over the ``wire`` transport — the printed RTT and byte
counts are measured on the socket, not simulated (docs/transport.md) —
including mid-session slot-pool churn: one stream detaches and a late
joiner takes over its (server-side re-leased, zeroed) slot.

Run:  PYTHONPATH=src python examples/serve_collaborative.py --arch granite-8b
      PYTHONPATH=src python examples/serve_collaborative.py \
          --arch granite-8b --wire
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import subprocess
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data import tokens as tok
from repro.serving import SessionConfig, TransportSpec
from repro.serving.collaborative import CollaborativeEngine
from repro.training.loop import train_collab_lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=registry.names())
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--length", type=int, default=48)
    ap.add_argument("--latency-ms", type=float, default=20.0,
                    help="simulated server round trip for the async demo")
    ap.add_argument("--max-staleness", type=int, default=8,
                    help="async merge window in edge steps (0 = strict sync)")
    ap.add_argument("--wire", action="store_true",
                    help="also serve across a real correction-server "
                         "subprocess over a Unix socket (measured RTT)")
    args = ap.parse_args()

    cfg = registry.get_smoke(args.arch)
    print(f"training monitor briefly ({args.train_steps} steps)...")
    batches = tok.lm_batches(0, cfg, args.streams, 64)
    params, _ = train_collab_lm(jax.random.PRNGKey(0), cfg, batches,
                                steps=args.train_steps, lr=1e-3, log_every=20)

    print(f"\nserving {args.streams} streams x {args.length} tokens "
          f"(threshold={cfg.monitor.threshold}, "
          f"margin={cfg.monitor.trigger_margin})")
    stream = next(tok.lm_batches(9, cfg, args.streams, args.length))["tokens"]
    eng = CollaborativeEngine(params, cfg, batch=args.streams,
                              max_len=args.length + 8)
    session = eng.session()  # sync MonitorSession: the online protocol
    t0 = time.time()
    res = session.run(stream)
    dt_loop = time.time() - t0

    for b in range(args.streams):
        trace = "".join("!" if t else "." for t in res["triggered"][b])
        print(f"  stream {b}: {trace}")
    rep = res["comms"]
    print(f"\ntrigger rate {rep['trigger_rate']:.3f}  |  "
          f"bytes {rep['bytes_sent']:,} vs baseline {rep['bytes_baseline']:,} "
          f"->  {rep['reduction_x']:.1f}x communication reduction")
    per = rep["per_stream"]
    for b in range(args.streams):
        print(f"  stream {b}: shipped {per['bytes_sent'][b]:,}B "
              f"(reduction {per['reduction_x'][b]:.1f}x)")
    print("fhat <= u everywhere:",
          bool(np.all(res["fhat"] <= res["u"] + 1e-6)))

    # offline fast path: same traces, one compiled lax.scan
    scan_eng = CollaborativeEngine(params, cfg, batch=args.streams,
                                   max_len=args.length + 8)
    scan_sess = scan_eng.session(SessionConfig(mode="scan"))
    scan_sess.run(stream)  # compile
    t0 = time.time()
    res_scan = scan_sess.run(stream)
    dt_scan = time.time() - t0
    same_u = np.array_equal(res_scan["u"], res["u"])
    same_trig = np.array_equal(res_scan["triggered"], res["triggered"])
    tps_scan = args.streams * args.length / max(dt_scan, 1e-9)
    print(f"\nscan fast path: {tps_scan:.0f} tok/s offline re-evaluation, "
          f"{dt_loop / max(dt_scan, 1e-9):.1f}x vs the online loop's first "
          f"run (which includes jit warmup); u identical: {same_u}, "
          f"triggers identical: {same_trig}")

    # async pipelined serving against a mock-remote server: triggers
    # dispatch the catch-up and the edge loop keeps decoding; corrections
    # merge one step late (docs/protocol.md)
    aeng = CollaborativeEngine(params, cfg, batch=args.streams,
                               max_len=args.length + 8)
    acfg = SessionConfig(
        mode="async", max_staleness=args.max_staleness,
        transport=TransportSpec("stream", latency_s=args.latency_ms * 1e-3))
    with aeng.session(acfg) as asess:
        res_async = asess.run(stream)
    print(f"\nasync pipelined ({args.latency_ms:.0f} ms simulated RTT, "
          f"max_staleness={args.max_staleness}): "
          f"u identical: {np.array_equal(res_async['u'], res['u'])}, "
          f"triggers identical: "
          f"{np.array_equal(res_async['triggered'], res['triggered'])}")
    if "async" in res_async["comms"]:  # absent when nothing ever triggered
        rep_a = res_async["comms"]["async"]
        print(f"  {rep_a['requests']} catch-up requests, "
              f"{rep_a['merged_late']} merged late, "
              f"overlap ratio {rep_a['overlap_ratio']:.2f}, "
              f"edge stall {rep_a['stall_s'] * 1e3:.0f} ms total")
    print("  safety under staleness (fhat <= u):",
          bool(np.all(res_async["fhat"] <= res_async["u"] + 1e-6)))

    if not args.wire:
        return

    # the real boundary: checkpoint the trained params, hand them to a
    # correction-server SUBPROCESS, and serve the same streams over the
    # wire transport — both processes restore the same checkpoint, so
    # only protocol bytes (backlog tokens + scores) cross the socket
    from repro.launch.server import spawn_subprocess
    from repro.training import checkpoint as ckpt
    tmp = tempfile.mkdtemp(prefix="serve_wire_")
    ckpt_dir = os.path.join(tmp, "ckpt")
    ckpt.save(ckpt_dir, args.train_steps, params)
    uds = os.path.join(tmp, "corr.sock")
    proc = spawn_subprocess(args.arch, uds=uds, slots=args.streams,
                            max_len=args.length + 8, ckpt_dir=ckpt_dir,
                            ready_file=os.path.join(tmp, "ready"),
                            quiet=False)
    try:
        weng = CollaborativeEngine(params, cfg, batch=args.streams,
                                   max_len=args.length + 8)
        wcfg = SessionConfig(mode="async", max_staleness=args.max_staleness,
                             transport=TransportSpec("wire", address=uds))
        with weng.session(wcfg) as wsess:
            # mid-session churn across the REAL boundary: retire stream 0,
            # admit a fresh device into the freed slot (the server zeroes
            # and re-leases the single super-batch row)
            for t in range(args.length // 2):
                wsess.step(jnp.asarray(stream[:, t]))
            wsess.detach(0)
            wsess.attach("late-joiner")
            for t in range(args.length // 2, args.length):
                toks = {sid: stream[sid, t] for sid in wsess.streams
                        if sid != "late-joiner"}
                toks["late-joiner"] = stream[0, t - args.length // 2]
                wsess.step(toks)
        res_wire = {"comms": weng.comms.report()}
        print("\nwire transport (two processes, UDS, with mid-session "
              "attach/detach of one stream):")
        w = res_wire["comms"].get("wire", {})
        if w:
            print(f"  measured on the socket: {w['tx_bytes']:,}B tx / "
                  f"{w['rx_bytes']:,}B rx, RTT mean "
                  f"{w['rtt_mean_s'] * 1e3:.2f} ms / max "
                  f"{w['rtt_max_s'] * 1e3:.2f} ms "
                  f"over {w['replies']} replies")
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


if __name__ == "__main__":
    main()
